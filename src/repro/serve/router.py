"""Fleet router: prefix-aware dispatch over N independent serve engines.

The paper's core economy - keep data where it already lives instead of
round-tripping it through a shared buffer - applies one level above the
kernel: a request whose KV prefix is already resident on some replica
should LAND on that replica, not recompute the prefix somewhere else.
This module is that scheduling layer.  A `FleetRouter` fronts N
independent `ServeEngine` replicas (each with its own page pool, radix
prefix tree, scheduler, and telemetry registry) and owns the fleet
lifecycle: `submit()` / `tick()` (`step()`) / `run_until_done()` mirror
the single-engine API, so callers swap an engine for a fleet without
code changes.

Dispatch is a cache-hit-weighted score, evaluated per submit:

  score(r) = saved_r
             - load_weight     * outstanding_work_r
             - pressure_weight * page_shortfall_r * page_size

  saved_r            prompt tokens replica r's radix tree already caches,
                     read with the side-effect-free `RadixPrefixCache.
                     peek()` - peeking N-1 losing replicas must not bump
                     their LRU stamps, refcounts, or hit counters (a
                     router probe is not a hit).  Capped at len(prompt)-1
                     because a fully cached prompt still recomputes its
                     last token for logits.
  outstanding_work_r replica r's queued + in-flight work tokens (prompt
                     remaining + unspent generation budget), from the
                     engine's registry-backed `load_stats()` - the
                     queue-depth / in-flight-work term.
  page_shortfall_r   pages of the request's reservation that replica r
                     could not grant right now even after LRU eviction
                     (free + evictable headroom) - the page-pool-pressure
                     term, scaled to tokens by page_size.

All three terms are deterministic host-side integers; ties break to the
LOWEST replica index, so a replayed trace routes bit-identically.
Placement is STICKY: a request never migrates after submit (its KV pages
live in one replica's pool; preemption inside a replica parks and
resumes there).  Per-replica admission backpressure is a queue-depth cap
(`spill_queue_depth`): when the best-scoring replica's queue is at the
cap the request SPILLS to the next-best under the cap (counted in
`fleet_spills_total`); if every replica is at the cap the best one takes
it anyway - the cap sheds imbalance, it never rejects work.

Fleet telemetry: the router has its own `MetricsRegistry` (dispatch /
spill / affinity-hit counters, per-replica dispatch labels),
`fleet_snapshot()` adds a summed view over every replica's registry,
`fleet_stats()` aggregates the engines' `stats()`, and `export_trace()`
merges every replica's Perfetto trace into one file with one process
(track group) per replica.

Because jitted serve steps are SHARED per model across engines
(`engine._shared_steps`), every replica runs the very same compiled
executables - greedy outputs for a given request are bit-identical
whichever replica serves it, which is what makes the differential
1-replica-vs-N-replica conformance suite (tests/test_router.py) exact
rather than approximate.
"""
from __future__ import annotations

import json
import math
from dataclasses import dataclass
from enum import Enum
from typing import Any, Dict, List, Optional, Sequence, Tuple

from ..configs.base import ServeConfig
from ..models import Model
from .engine import ServeEngine
from .paged_cache import pages_needed
from .scheduler import Request, RequestState, TERMINAL_STATES
from .telemetry import MetricsRegistry


class ReplicaState(str, Enum):
    """Replica lifecycle the router drives:

        HEALTHY --drain()--> DRAINING --undrain()--> HEALTHY
           |                     |
         fail() / watchdog     fail() / watchdog
           v                     v
          DEAD <---------------DEAD          (terminal)

    HEALTHY replicas receive new dispatch; DRAINING replicas stop
    receiving dispatch but keep ticking until their queue and slots empty
    (then stay DRAINING, parked, until undrain()); DEAD replicas are
    never ticked again and their queued + in-flight requests are
    REDISPATCHED to survivors through the resume path."""
    HEALTHY = "healthy"
    DRAINING = "draining"
    DEAD = "dead"


@dataclass(frozen=True)
class FleetConfig:
    """Router-level knobs (per-replica behavior stays in ServeConfig)."""
    n_replicas: int = 2
    policy: str = "affinity"        # affinity | round_robin
    # score weights: tokens of cached prefix a unit of each term is worth
    load_weight: float = 0.1        # per outstanding work token
    pressure_weight: float = 4.0    # per token of ungrantable reservation
    # per-replica admission backpressure: spill to the next-best replica
    # when the chosen one has this many requests queued (0 = off)
    spill_queue_depth: int = 0
    # SLO-aware dispatch: subtract slo_weight * (replica's observed
    # work-clock p95 TTFT over its finished requests) from the score, so
    # a replica that has been DELIVERING slow first tokens sheds load to
    # faster peers even when raw outstanding work looks comparable.
    # 0 (default) = off, bit-identical to pre-SLO routing.
    slo_weight: float = 0.0
    # health probe: a replica with outstanding work whose work clock has
    # not advanced for this many consecutive fleet ticks is declared DEAD
    # (tick watchdog) and its requests redispatch to survivors.  0 = off.
    watchdog_ticks: int = 0

    def validate(self) -> "FleetConfig":
        if self.n_replicas < 1:
            raise ValueError(f"n_replicas must be >= 1, "
                             f"got {self.n_replicas}")
        if self.policy not in ("affinity", "round_robin"):
            raise ValueError(f"policy must be 'affinity' or 'round_robin', "
                             f"got {self.policy!r}")
        if self.load_weight < 0 or self.pressure_weight < 0 \
                or self.slo_weight < 0:
            raise ValueError("score weights must be >= 0")
        if self.spill_queue_depth < 0:
            raise ValueError(f"spill_queue_depth must be >= 0, "
                             f"got {self.spill_queue_depth}")
        if self.watchdog_ticks < 0:
            raise ValueError(f"watchdog_ticks must be >= 0 (0 = off), "
                             f"got {self.watchdog_ticks}")
        return self


class FleetRouter:
    """N serve-engine replicas behind one engine-shaped front door."""

    def __init__(self, model: Model, params, scfg: ServeConfig,
                 fcfg: Optional[FleetConfig] = None):
        self.fcfg = (fcfg or FleetConfig()).validate()
        self.scfg = scfg
        # replicas share the model/params (and therefore the jitted steps:
        # identical executables => bit-identical numerics across replicas)
        self.engines: List[ServeEngine] = [
            ServeEngine(model, params, scfg)
            for _ in range(self.fcfg.n_replicas)]
        # fleet uid -> (replica index, replica-local Request); fleet uids
        # are issued in submit order, so the SAME trace through different
        # fleet sizes keys its outputs identically
        self._fuid = 0
        self.placement: Dict[int, int] = {}
        self.requests: Dict[int, Request] = {}
        self._rr_next = 0               # round_robin cursor
        # replica lifecycle (HEALTHY -> DRAINING -> DEAD): DEAD replicas
        # are never ticked or invariant-checked again (their host-side
        # state is abandoned wholesale - that is what "lost" means)
        self.states: List[ReplicaState] = \
            [ReplicaState.HEALTHY] * self.fcfg.n_replicas
        # tick watchdog: last observed work clock + consecutive stale
        # ticks per replica (a busy replica whose clock freezes is wedged)
        self._last_work = [0] * self.fcfg.n_replicas
        self._stale_ticks = [0] * self.fcfg.n_replicas
        # fleet tick a drain() started on, until the replica empties
        self._drain_start: Dict[int, int] = {}
        # requests that went terminal AT THE ROUTER (FAILED: retry budget
        # spent during a fail()); drained into the next tick()'s finished
        # list so run_until_done callers see every terminal request
        self._terminated: List[Request] = []
        self.metrics = MetricsRegistry()
        m = self.metrics
        m.counter("fleet_requests_total", "Requests accepted by the router")
        m.counter("fleet_dispatch_total",
                  "Requests dispatched, per replica", labelnames=("replica",))
        m.counter("fleet_spills_total",
                  "Dispatches diverted off the best-scoring replica by the "
                  "spill_queue_depth admission cap")
        m.counter("fleet_affinity_hits_total",
                  "Dispatches whose chosen replica already cached >= 1 "
                  "prompt page at decision time")
        m.counter("fleet_affinity_hit_tokens_total",
                  "Prompt tokens already cached on the chosen replica at "
                  "decision time (peek-measured, whole pages)")
        m.counter("fleet_ticks_total",
                  "Fleet ticks (one tick of every replica)")
        m.gauge("fleet_replicas", "Engine replicas fronted by this router")
        m.get("fleet_replicas").set(self.fcfg.n_replicas)
        # --- fault tolerance ------------------------------------------
        m.gauge("fleet_replica_state",
                "Replica lifecycle state (0 = healthy, 1 = draining, "
                "2 = dead)", labelnames=("replica",))
        m.counter("fleet_drains_total", "drain() calls accepted")
        m.counter("fleet_failures_total",
                  "Replicas declared dead (fail() or watchdog)")
        m.counter("fleet_watchdog_trips_total",
                  "Replica failures declared by the tick watchdog "
                  "(busy replica, frozen work clock)")
        m.counter("fleet_redispatches_total",
                  "Requests moved off a dead replica onto a survivor "
                  "(resume-path re-entry)")
        m.counter("fleet_retries_exhausted_total",
                  "Requests gone terminal FAILED because a redispatch "
                  "would exceed their max_retries budget")
        m.histogram("fleet_drain_duration_ticks",
                    "Fleet ticks from drain() to the replica emptying",
                    buckets=(1, 2, 4, 8, 16, 32, 64, 128, 256))
        for i in range(self.fcfg.n_replicas):
            m.get("fleet_replica_state").labels(str(i)).set(0)

    # ------------------------------------------------------------------
    # dispatch scoring
    # ------------------------------------------------------------------
    def _peek_saved(self, eng: ServeEngine,
                    prompt: Sequence[int]) -> Tuple[int, int, bool]:
        """(saved_tokens, cached_pages, full_cover) on one replica, via
        the side-effect-free peek - probing must not perturb the replica's
        LRU order, refcounts, or hit accounting."""
        if eng.prefix is None:
            return 0, 0, False
        pages = eng.prefix.peek(prompt)
        ps = eng.scfg.page_size
        full = len(pages) * ps >= len(prompt)
        saved = min(len(pages) * ps, len(prompt) - 1)
        return saved, len(pages), full

    def _observed_ttft(self, eng: ServeEngine) -> float:
        """Replica's observed work-clock p95 TTFT over its finished
        requests (0.0 before any finishes).  Deterministic host-side
        integers in, nearest-rank percentile out - no numpy, no device
        reads - so SLO-weighted dispatch replays bit-identically."""
        vals = sorted(r.ttft_work() for r in eng.sched.finished
                      if r.token_work)
        if not vals:
            return 0.0
        return float(vals[max(0, math.ceil(0.95 * len(vals)) - 1)])

    def _score(self, ridx: int, prompt: Sequence[int],
               n_new: int) -> Tuple[float, int]:
        """(score, saved_tokens) of dispatching to replica `ridx`.  All
        inputs are deterministic host-side state; equal scores are broken
        by replica index at the call site."""
        eng = self.engines[ridx]
        saved, n_cached, full = self._peek_saved(eng, prompt)
        load = eng.load_stats()
        pressure = 0
        if eng.paged:
            need = pages_needed(len(prompt) + n_new, eng.scfg.page_size)
            # cached pages are attached, not allocated - but a fully
            # cached prompt COWs its final page, which costs one fresh one
            need -= max(0, n_cached - (1 if full else 0))
            headroom = load["free_pages"] + load["evictable_pages"]
            pressure = max(0, need - headroom)
        score = (saved
                 - self.fcfg.load_weight * load["outstanding_work_tokens"]
                 - self.fcfg.pressure_weight * pressure
                 * eng.scfg.page_size)
        if self.fcfg.slo_weight:
            # the SLO term: what this replica has been DELIVERING, not
            # just what it is holding - a replica with a history of slow
            # first tokens sheds new load to faster peers
            score -= self.fcfg.slo_weight * self._observed_ttft(eng)
        return score, saved

    def _choose(self, prompt: Sequence[int],
                n_new: int) -> Tuple[int, int, int]:
        """(chosen replica, best-scoring replica, saved tokens on the
        chosen one).  chosen != best iff the admission cap spilled.  Only
        HEALTHY replicas are candidates: DRAINING replicas take no new
        dispatch (that is the point of draining) and DEAD ones are gone;
        with no healthy replica left the router refuses the request
        loudly rather than queueing it onto a corpse."""
        n = len(self.engines)
        healthy = [i for i in range(n)
                   if self.states[i] is ReplicaState.HEALTHY]
        if not healthy:
            raise RuntimeError(
                "no healthy replica to dispatch to: states "
                f"{[s.value for s in self.states]}")
        if self.fcfg.policy == "round_robin":
            base = self._rr_next % len(healthy)
            self._rr_next += 1
            order = [healthy[(base + k) % len(healthy)]
                     for k in range(len(healthy))]
            saved_of = {}               # peeked lazily, accounting only
        else:
            scored = {i: self._score(i, prompt, n_new) for i in healthy}
            # highest score wins; ties to the lowest index (sort is
            # stable and the key's second element pins the order), so
            # replays are bit-reproducible
            order = sorted(healthy, key=lambda i: (-scored[i][0], i))
            saved_of = {i: scored[i][1] for i in healthy}
        best = chosen = order[0]
        cap = self.fcfg.spill_queue_depth
        if cap:
            for i in order:
                if len(self.engines[i].queue) < cap:
                    chosen = i
                    break
            # every replica at the cap: the best one absorbs the request
            # (backpressure sheds imbalance, it never rejects work)
        if chosen not in saved_of:
            saved_of[chosen] = self._peek_saved(self.engines[chosen],
                                                prompt)[0]
        return chosen, best, saved_of[chosen]

    # ------------------------------------------------------------------
    # engine-shaped lifecycle
    # ------------------------------------------------------------------
    def submit(self, prompt: List[int],
               max_new_tokens: Optional[int] = None,
               stop_tokens: Optional[Sequence[int]] = None,
               priority: int = 0,
               deadline: Optional[int] = None,
               max_retries: Optional[int] = None) -> int:
        """Route one request and enqueue it on the chosen replica.
        Returns a FLEET uid (monotone in submit order, stable across
        fleet sizes); the placement is sticky for the request's life -
        unless its replica DIES, in which case the router redispatches it
        to a survivor (fail()).  `deadline` / `max_retries` pass through
        to the engine: a work-clock deadline (TIMEOUT on expiry) and the
        redispatch retry budget (terminal FAILED once spent)."""
        n_new = self.scfg.max_new_tokens if max_new_tokens is None \
            else max_new_tokens
        ridx, best, saved = self._choose(prompt, n_new)
        eng = self.engines[ridx]
        eng.submit(prompt, max_new_tokens, stop_tokens, priority,
                   deadline=deadline, max_retries=max_retries)
        req = eng.sched.queue[-1]
        self._fuid += 1
        fuid = self._fuid
        req.fleet_uid = fuid            # stamped for finished-tick callers
        self.placement[fuid] = ridx
        self.requests[fuid] = req
        m = self.metrics
        m.get("fleet_requests_total").inc()
        m.get("fleet_dispatch_total").labels(str(ridx)).inc()
        if ridx != best:
            m.get("fleet_spills_total").inc()
        if saved > 0:
            m.get("fleet_affinity_hits_total").inc()
            m.get("fleet_affinity_hit_tokens_total").inc(saved)
        return fuid

    # ------------------------------------------------------------------
    # replica lifecycle: drain / fail / redispatch
    # ------------------------------------------------------------------
    def _set_state(self, ridx: int, state: ReplicaState):
        self.states[ridx] = state
        level = {ReplicaState.HEALTHY: 0, ReplicaState.DRAINING: 1,
                 ReplicaState.DEAD: 2}[state]
        self.metrics.get("fleet_replica_state").labels(str(ridx)).set(level)

    def drain(self, ridx: int):
        """Stop dispatching NEW requests to replica `ridx` and let it
        empty: it keeps ticking, its queued and in-flight requests run to
        completion in place (placement stays sticky - nothing migrates),
        and once its queue and slots are empty the drain duration lands
        in `fleet_drain_duration_ticks`.  The replica then stays parked
        (DRAINING) until undrain() returns it to rotation."""
        if self.states[ridx] is ReplicaState.DEAD:
            raise ValueError(f"replica {ridx} is dead; dead replicas "
                             f"cannot drain")
        if self.states[ridx] is ReplicaState.DRAINING:
            return
        self._set_state(ridx, ReplicaState.DRAINING)
        self._drain_start[ridx] = \
            int(self.metrics.get("fleet_ticks_total").value)
        self.metrics.get("fleet_drains_total").inc()

    def undrain(self, ridx: int):
        """Return a DRAINING replica to dispatch rotation."""
        if self.states[ridx] is ReplicaState.DEAD:
            raise ValueError(f"replica {ridx} is dead; dead replicas "
                             f"cannot rejoin the fleet")
        if self.states[ridx] is ReplicaState.HEALTHY:
            return
        self._drain_start.pop(ridx, None)
        self._set_state(ridx, ReplicaState.HEALTHY)

    def fail(self, ridx: int) -> List[int]:
        """Declare replica `ridx` DEAD and redispatch every request it
        still owed - queued AND in-flight - to surviving replicas.  The
        dead engine is never ticked again; its host/device state is
        abandoned wholesale (that is what losing a replica means), which
        is why survivors' page conservation is the invariant that
        matters, not the corpse's.

        Redispatch re-enters through the RESUME path: a request with
        generated tokens re-submits on the survivor with resume_tokens =
        prompt + generated-so-far, exactly like a preemption victim - the
        chunk path rebuilds its KV (reusing any prefix-cached pages the
        survivor already holds) and the final resume chunk's logits
        sample the next token bit-identically to an undisturbed run.  A
        request whose max_retries budget is already spent goes terminal
        FAILED instead (surfaced through outputs()/statuses() and the
        next tick's finished list).  Returns the redispatched fleet uids.
        Idempotent: failing a dead replica is a no-op."""
        if self.states[ridx] is ReplicaState.DEAD:
            return []
        self._set_state(ridx, ReplicaState.DEAD)
        self._drain_start.pop(ridx, None)
        self.metrics.get("fleet_failures_total").inc()
        lost = sorted(f for f, r in self.placement.items()
                      if r == ridx and not self.requests[f].done)
        moved: List[int] = []
        m = self.metrics
        for fuid in lost:
            req = self.requests[fuid]
            if req.max_retries is not None \
                    and req.n_redispatches >= req.max_retries:
                req.state = RequestState.FAILED
                req.done = True
                req.finish_reason = "failed"
                m.get("fleet_retries_exhausted_total").inc()
                self._terminated.append(req)
                continue
            if req.out_tokens and not self.scfg.chunked:
                raise RuntimeError(
                    "in-flight failure recovery requires chunked=True: "
                    "a mid-decode request resumes through the chunk path")
            self._redispatch(fuid, req)
            moved.append(fuid)
        return moved

    def _redispatch(self, fuid: int, old: Request):
        """Move one lost request onto the best surviving replica.  The
        fleet uid is PRESERVED (outputs()/statuses() keys never change);
        the replica-local Request is fresh - survivor-local uid, fresh
        latency stamps on the survivor's work clock - carrying over the
        prompt, generated tokens, priority, stop set, deadline, and retry
        accounting.  With prior output the fresh request enters RESUMING
        with resume_tokens = prompt + generated (the preemption-resume
        contract); mid-prefill progress on the corpse is simply lost and
        re-prefills (the survivor's prefix cache absorbs what it can)."""
        ridx, best, saved = self._choose(old.prompt, old.max_new_tokens)
        eng = self.engines[ridx]
        eng.submit(old.prompt, old.max_new_tokens,
                   stop_tokens=old.stop_tokens, priority=old.priority,
                   deadline=old.deadline_tokens,
                   max_retries=old.max_retries)
        req = eng.sched.queue[-1]
        req.fleet_uid = fuid
        req.n_redispatches = old.n_redispatches + 1
        if old.out_tokens:
            req.out_tokens = list(old.out_tokens)
            req.resume_tokens = old.prompt + list(old.out_tokens)
            req.state = RequestState.RESUMING
        self.placement[fuid] = ridx
        self.requests[fuid] = req
        m = self.metrics
        m.get("fleet_redispatches_total").inc()
        m.get("fleet_dispatch_total").labels(str(ridx)).inc()
        if saved > 0:
            m.get("fleet_affinity_hits_total").inc()
            m.get("fleet_affinity_hit_tokens_total").inc(saved)

    def _collect_terminated(self) -> List[Request]:
        out, self._terminated = self._terminated, []
        return out

    def _run_watchdog(self) -> List[Request]:
        """The health probe: a replica that HAS work (queued or in
        flight) but whose work clock froze for watchdog_ticks consecutive
        fleet ticks is wedged - declare it dead and redispatch.  Work is
        the right staleness signal (not tick counts): a wedged engine may
        well keep 'ticking' while executing nothing."""
        finished: List[Request] = []
        for i, eng in enumerate(self.engines):
            if self.states[i] is ReplicaState.DEAD:
                continue
            busy = bool(eng.queue) or any(s is not None for s in eng.slots)
            work = eng.sched.work_clock
            if busy and work == self._last_work[i]:
                self._stale_ticks[i] += 1
                if self._stale_ticks[i] >= self.fcfg.watchdog_ticks:
                    self.metrics.get("fleet_watchdog_trips_total").inc()
                    self.fail(i)
                    finished.extend(self._collect_terminated())
            else:
                self._stale_ticks[i] = 0
            self._last_work[i] = work
        return finished

    def _note_drained(self):
        """Close out drain-duration accounting for replicas that emptied."""
        now = int(self.metrics.get("fleet_ticks_total").value)
        for ridx in list(self._drain_start):
            eng = self.engines[ridx]
            if not eng.queue and all(s is None for s in eng.slots):
                self.metrics.get("fleet_drain_duration_ticks").observe(
                    now - self._drain_start.pop(ridx))

    def tick(self) -> List[Request]:
        """One fleet iteration: every LIVE replica ticks once, in replica
        order (replicas are independent, so the order is cosmetic - but
        fixed, for deterministic merged telemetry); DEAD replicas are
        skipped forever.  Returns the requests that went terminal this
        tick - finished, timed out, or router-FAILED - each stamped with
        `.fleet_uid`."""
        finished: List[Request] = self._collect_terminated()
        for i, eng in enumerate(self.engines):
            if self.states[i] is ReplicaState.DEAD:
                continue
            finished.extend(eng.tick())
        self.metrics.get("fleet_ticks_total").inc()
        if self.fcfg.watchdog_ticks:
            finished.extend(self._run_watchdog())
        self._note_drained()
        return finished

    # the engine API spells one iteration `tick`; `step` is the router
    # alias some fleet-level callers prefer
    step = tick

    def statuses(self) -> Dict[int, str]:
        """{fleet uid: terminal-or-live state} for every submitted
        request: "done" | "timeout" | "failed" for terminal requests,
        else the live scheduler state ("queued", "prefilling", ...)."""
        return {fuid: r.state.value for fuid, r in self.requests.items()}

    def run_until_done(self, max_ticks: int = 10_000,
                       on_exhaust: str = "raise") -> List[Request]:
        """Tick until every LIVE replica's queue and slots drain (same
        semantics as ServeEngine.run_until_done).  On tick exhaustion
        with on_exhaust="return", the warning reports per-request
        terminal statuses (done/timeout/failed counts) and names the
        fleet uids still running, so a stalled fleet is diagnosable from
        the warning alone."""
        done: List[Request] = []
        for _ in range(max_ticks):
            done.extend(self.tick())
            if self.idle:
                return done
        if self.idle:
            return done
        pending = sum(
            len(e.queue) + sum(s is not None for s in e.slots)
            for i, e in enumerate(self.engines)
            if self.states[i] is not ReplicaState.DEAD)
        by_status: Dict[str, int] = {}
        running: List[int] = []
        for fuid, r in self.requests.items():
            by_status[r.state.value] = by_status.get(r.state.value, 0) + 1
            if r.state not in TERMINAL_STATES:
                running.append(fuid)
        msg = (f"FleetRouter.run_until_done: {max_ticks} ticks exhausted "
               f"with {pending} requests still pending "
               f"({len(done)} finished); statuses: "
               f"{dict(sorted(by_status.items()))}; still running "
               f"fleet uids: {sorted(running)}")
        if on_exhaust == "raise":
            raise RuntimeError(msg)
        import warnings
        warnings.warn(msg)
        return done

    @property
    def idle(self) -> bool:
        """True when no live replica holds work (DEAD replicas are
        abandoned state, not pending work - their lost requests were
        either redispatched or went terminal FAILED at fail() time)."""
        return all(not e.queue and all(s is None for s in e.slots)
                   for i, e in enumerate(self.engines)
                   if self.states[i] is not ReplicaState.DEAD)

    def outputs(self) -> Dict[int, List[int]]:
        """{fleet uid: generated tokens} for every submitted request -
        the differential-conformance view (fleet uids are submit-ordered,
        so 1-replica and N-replica runs of one trace key identically)."""
        return {fuid: list(r.out_tokens)
                for fuid, r in self.requests.items()}

    def check_invariants(self):
        """Every LIVE replica's engine invariants plus the router's own
        bookkeeping: placements in range, dispatch counters conserved.
        DEAD replicas are skipped - a failed engine's internal state is
        abandoned, not repaired; what must stay consistent is the
        survivors and the router's request ledger."""
        for i, eng in enumerate(self.engines):
            if self.states[i] is not ReplicaState.DEAD:
                eng.check_invariants()
        n = len(self.engines)
        assert all(0 <= r < n for r in self.placement.values()), \
            "placement outside the fleet"
        dispatched = sum(
            child.value for _, child in
            self.metrics.get("fleet_dispatch_total").label_items())
        redispatched = self.metrics.get("fleet_redispatches_total").value
        assert len(self.placement) \
            == self.metrics.get("fleet_requests_total").value, \
            "placement ledger out of sync with submissions"
        assert dispatched == len(self.placement) + redispatched, \
            "dispatch accounting out of sync with placements + redispatches"

    # ------------------------------------------------------------------
    # fleet telemetry
    # ------------------------------------------------------------------
    _SUM_KEYS = ("requests", "work_tokens", "gen_tokens", "prefill_tokens",
                 "prefix_hit_tokens", "prompt_tokens", "jit_calls",
                 "host_syncs", "chunks_run", "packs_run", "preemptions",
                 "resumes", "priority_boosts", "cow_copies", "timeouts")

    def dispatch_counts(self) -> List[int]:
        """Requests dispatched per replica, replica order."""
        by_label = dict(self.metrics.get("fleet_dispatch_total")
                        .label_items())
        return [int(by_label[(str(i),)].value) if (str(i),) in by_label
                else 0 for i in range(len(self.engines))]

    def fleet_stats(self) -> Dict[str, Any]:
        """Aggregated engine stats (summed per-replica counters) plus the
        router's dispatch accounting - the fleet analog of
        ServeEngine.stats()."""
        per = [e.stats() for e in self.engines]
        out: Dict[str, Any] = {
            k: sum(s[k] for s in per) for k in self._SUM_KEYS}
        out["n_replicas"] = len(self.engines)
        out["policy"] = self.fcfg.policy
        # every replica shares one ServeConfig, so one degree describes
        # the fleet (docs/tensor_parallel.md); stats() sums would be
        # meaningless for a degree
        out["tp_degree"] = self.scfg.tp_degree
        out["ticks"] = int(self.metrics.get("fleet_ticks_total").value)
        out["dispatch"] = self.dispatch_counts()
        out["spills"] = int(self.metrics.get("fleet_spills_total").value)
        out["affinity_hits"] = int(
            self.metrics.get("fleet_affinity_hits_total").value)
        out["affinity_hit_tokens"] = int(
            self.metrics.get("fleet_affinity_hit_tokens_total").value)
        out["replica_states"] = [s.value for s in self.states]
        out["redispatches"] = int(
            self.metrics.get("fleet_redispatches_total").value)
        out["failures"] = int(
            self.metrics.get("fleet_failures_total").value)
        out["drains"] = int(self.metrics.get("fleet_drains_total").value)
        out["retries_exhausted"] = int(
            self.metrics.get("fleet_retries_exhausted_total").value)
        out["per_replica"] = per
        return out

    @staticmethod
    def _sum_value(acc: Dict[str, Any], name: str, value: Any):
        """Fold one replica's metric value into the summed view: scalars
        add, labeled metrics add per label, histograms add count/sum."""
        if isinstance(value, dict):
            if "buckets" in value:          # histogram
                slot = acc.setdefault(name, {"count": 0, "sum": 0.0})
                slot["count"] += value["count"]
                slot["sum"] += value["sum"]
            else:                           # labeled children
                slot = acc.setdefault(name, {})
                for k, v in value.items():
                    slot[k] = slot.get(k, 0) + v
            return
        acc[name] = acc.get(name, 0) + value

    def fleet_snapshot(self) -> Dict[str, Any]:
        """The fleet registry view: the router's own metrics, every
        replica's full registry snapshot, and a `sum` section folding the
        per-replica counters/gauges together (gauges sum too - fleet
        queue depth is the sum of replica queue depths; peak watermarks
        become a fleet-wide upper bound)."""
        replicas = [e.metrics_snapshot() for e in self.engines]
        summed: Dict[str, Any] = {}
        for snap in replicas:
            for name, meta in snap.items():
                self._sum_value(summed, name, meta["value"])
        return {"router": self.metrics.snapshot(),
                "replicas": replicas,
                "sum": summed}

    def export_trace(self, path, clock: str = "wall") -> Dict[str, Any]:
        """Merge every replica's Perfetto trace into one file with one
        process-pair (engine + requests track group) per replica, pids
        offset so Perfetto renders `replica0:engine`, `replica0:requests`,
        `replica1:engine`, ...  Requires ServeConfig(telemetry=True).
        With clock="wall" the replicas share the host clock but not an
        epoch-aligned tracer start; clock="work" is the deterministic,
        replay-stable view."""
        events: List[Dict[str, Any]] = []
        for i, eng in enumerate(self.engines):
            trace = eng.export_trace(None, clock=clock)
            for ev in trace["traceEvents"]:
                ev = dict(ev)
                ev["pid"] = 2 * i + ev["pid"]
                if ev.get("ph") == "M" and ev.get("name") == "process_name":
                    ev["args"] = {
                        "name": f"replica{i}:{ev['args']['name']}"}
                events.append(ev)
        merged = {"traceEvents": events, "displayTimeUnit": "ms",
                  "otherData": {"clock": clock,
                                "n_replicas": len(self.engines)}}
        if path is not None:
            with open(path, "w") as f:
                json.dump(merged, f, indent=None, separators=(",", ":"))
        return merged
