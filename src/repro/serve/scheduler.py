"""Token-budget continuous-batching scheduler with chunked prefill.

The paper's core scheduling idea is LATENCY BALANCING: 3D-FlashAttention
splits attention into fine-grained tile chunks so no tier ever stalls
behind a long-running neighbor, forming a bubble-free pipeline.  A serve
engine has the same problem one level up: a monolithic admission-time
prefill of a 4k-token prompt stalls every active decode slot for the whole
prefill - a request-level pipeline bubble.  This module applies the same
cure at the same granularity knob: prompts are split into fixed-size
chunks (ServeConfig.prefill_chunk) and interleaved with decode inside a
fixed per-tick TOKEN BUDGET (ServeConfig.tick_token_budget), so decode
latency stays flat while long prompts stream in (Sarathi-style chunked
prefill / stall-free batching).

Per tick:

  budget = tick_token_budget
  - every DECODING slot consumes 1 token (decode is never descheduled);
  - the remaining budget is filled with prompt chunks for PREFILLING
    slots - the OLDEST request is guaranteed its chunk first (no
    starvation), the rest shortest-remaining-first (short interactive
    prompts reach their first token ahead of a 4k neighbor) - each chunk
    `prefill_chunk` tokens (the final chunk of a prompt may be shorter);
  - a chunk is scheduled only if it fits the remaining budget whole, so
    chunk starts stay page-aligned and the budget is a hard ceiling.

Request lifecycle (Request.state):

  QUEUED ──admit──> PREFILLING ──last chunk──> DECODING ──stop/len──> DONE
              (pages reserved,     (first token        (pages freed or
               cursor at cached     sampled from        published to the
               prefix end)          prompt logits)      prefix cache)

The scheduler is TENSOR-PARALLEL INVARIANT by construction: it plans in
tokens, slots, and pages - never devices - so ServeConfig.tp_degree does
not appear anywhere in admission, chunk packing, preemption, or the work
clock.  A tp=N engine therefore runs the identical tick plan as tp=1 on
the same trace, which is why the TP conformance suite can assert EQUAL
work-clock totals, not merely comparable ones (docs/tensor_parallel.md).

Admission policy is pluggable: "fifo" (arrival order) or "sjf" (shortest
prompt first - minimizes mean TTFT at the cost of long-prompt fairness).
Backpressure is per-policy head-of-line: when the chosen candidate cannot
be placed (no slot / no pages), admission stops for the tick.

The scheduler also owns per-request latency accounting.  Every emitted
token is stamped with wall-clock time AND the engine's WORK CLOCK (total
prefill + decode tokens executed so far): work-clock TTFT/TBT are exact,
deterministic measures of scheduling bubbles - a decode slot that waits
behind a monolithic 4k prefill sees a 4k-work gap between tokens - while
wall-clock numbers measure the same thing in (noisier) seconds.
`stats()` aggregates p50/p95 of both.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from enum import Enum
from typing import Dict, FrozenSet, List, Optional, Sequence, Tuple

import numpy as np

from ..configs.base import ServeConfig
from .drafting import ngram_draft
from .telemetry import MetricsRegistry


def _registry_counter(name: str):
    """Class-level compatibility view over a registry counter: reads and
    `self.x += n` writes on the old attribute names go straight through
    the MetricsRegistry, so the registry is the one source of truth while
    every existing call site (and test) keeps its spelling."""
    def fget(self):
        return int(self.metrics.get(name).value)

    def fset(self, v):
        self.metrics.get(name).set_total(v)

    return property(fget, fset)


class RequestState(str, Enum):
    QUEUED = "queued"            # submitted, waiting for a slot / pages
    PREFILLING = "prefilling"    # slot + pages held, prompt streaming in
    DECODING = "decoding"        # prompt complete, generating tokens
    RESUMING = "resuming"        # preempted: re-queued, pages shed, waiting
    DONE = "done"                # finished (length / stop token)
    TIMEOUT = "timeout"          # expired: work-clock deadline reached
    FAILED = "failed"            # terminal: redispatch retry budget spent


# the states a request can never leave (DONE / TIMEOUT / FAILED); anything
# else is still live - queued, in flight, or parked for resume
TERMINAL_STATES = frozenset((RequestState.DONE, RequestState.TIMEOUT,
                             RequestState.FAILED))


@dataclass
class Request:
    uid: int
    prompt: List[int]
    max_new_tokens: int
    stop_tokens: FrozenSet[int] = frozenset()
    priority: int = 0            # higher admits (and preempts) first
    out_tokens: List[int] = field(default_factory=list)
    done: bool = False
    state: RequestState = RequestState.QUEUED
    slot: Optional[int] = None
    # prompt tokens already resident in the KV cache (cached prefix +
    # chunks prefilled so far); the request's prefill cursor
    prefill_pos: int = 0
    finish_reason: str = ""      # "length" | "stop" | "timeout" | "failed"
    # --- deadlines / fault tolerance -------------------------------------
    # work-clock deadline: the request expires (TIMEOUT) once the engine
    # has executed this many work tokens since its submit (None = never).
    # Deterministic by construction - the work clock is.
    deadline_tokens: Optional[int] = None
    # redispatch retry budget (fleet-level): how many times the router may
    # move this request off a failed replica before it goes terminal
    # FAILED (None = unbounded)
    max_retries: Optional[int] = None
    n_redispatches: int = 0
    # --- preemption ------------------------------------------------------
    # monotone admission stamp (engine-issued): the preemption policy sheds
    # the most recently admitted PREFILLING victim first
    admit_seq: int = -1
    n_preemptions: int = 0
    n_resumes: int = 0
    # a DECODING victim's KV holds prompt + generated tokens; the resume
    # prefill must rebuild ALL of it before the next decode step, so this
    # snapshot replaces `prompt` as the chunk path's target (None until the
    # request is preempted mid-decode)
    resume_tokens: Optional[List[int]] = None
    # --- latency accounting (wall seconds + engine work-clock tokens) ----
    # stamps are carried across preempt/resume, never reset: TTFT/TBT stay
    # monotone and a resume delay shows up as a (real) latency gap
    t_submit: float = 0.0
    w_submit: int = 0
    token_wall: List[float] = field(default_factory=list)
    token_work: List[int] = field(default_factory=list)
    token_tick: List[int] = field(default_factory=list)

    @property
    def target(self) -> List[int]:
        """The token sequence the chunk-prefill path must make resident:
        the prompt, or - resuming after a mid-decode preemption - the
        prompt plus every token generated before the preemption (the final
        resume chunk's logits then sample the NEXT token, exactly as the
        uninterrupted decode would have)."""
        return self.prompt if self.resume_tokens is None \
            else self.resume_tokens

    @property
    def remaining_new(self) -> int:
        """Generation budget still unspent (resume reservations size pages
        to target + remaining_new = prompt + max_new, same as admission)."""
        return self.max_new_tokens - len(self.out_tokens)

    @property
    def prompt_remaining(self) -> int:
        return len(self.target) - self.prefill_pos

    def ttft_wall(self) -> Optional[float]:
        return self.token_wall[0] - self.t_submit if self.token_wall else None

    def ttft_work(self) -> Optional[int]:
        return self.token_work[0] - self.w_submit if self.token_work else None

    def tbt_wall(self) -> List[float]:
        return [b - a for a, b in zip(self.token_wall, self.token_wall[1:])]

    def tbt_work(self) -> List[int]:
        return [b - a for a, b in zip(self.token_work, self.token_work[1:])]


@dataclass(frozen=True)
class ChunkTask:
    """One planned prefill chunk: `length` prompt tokens of `req` starting
    at absolute position `start`, to run in slot `slot` this tick."""
    req: Request
    slot: int
    start: int
    length: int


def bucket_rows(k: int) -> int:
    """Round a chunk-batch row count up to the next power of two.  The
    batched chunk step compiles once per (row-bucket, chunk-shape) pair,
    so bucketing bounds steady-state recompiles to log2(max rows) shapes
    instead of one per distinct K the planner happens to emit."""
    b = 1
    while b < k:
        b *= 2
    return b


@dataclass(frozen=True)
class ChunkBatch:
    """One tick's planned chunks packed into a device-ready ragged batch:
    row r of every array describes tasks[r]; rows past len(tasks) are DEAD
    padding up to the power-of-two bucket (zero tokens, offset 0,
    true_len 0, sentinel slot, and - engine-side - an all-null block-table
    row), so they compute nothing and update nothing."""
    tasks: Tuple[ChunkTask, ...]
    tokens: np.ndarray      # (K_pad, S_pad) int32, each row zero-padded
    offsets: np.ndarray     # (K_pad,) int32 absolute chunk starts
    true_lens: np.ndarray   # (K_pad,) int32 cursors AFTER each chunk
    # slot of each row whose chunk COMPLETES its prompt; non-final and
    # padding rows carry the out-of-range sentinel max_batch, which the
    # batched step's mode="drop" scatter discards
    final_slots: np.ndarray  # (K_pad,) int32
    row_slots: np.ndarray    # (K_pad,) int32 owning slot, -1 for padding

    @property
    def k_real(self) -> int:
        return len(self.tasks)


@dataclass(frozen=True)
class DraftTask:
    """One planned speculative verify lane: `draft` proposed tokens for
    `req` (DECODING in slot `slot`), whose KV frontier sits at absolute
    position `offset` (= the slot's lens at planning time).  The verify
    row's tokens are [pending, *draft]: the pending token's KV write plus
    the draft chain, scored in one ragged-chunk launch."""
    req: Request
    slot: int
    offset: int
    draft: Tuple[int, ...]


@dataclass(frozen=True)
class SpecBatch:
    """One tick's planned draft chains packed into a device-ready ragged
    batch for the verify step: row r describes tasks[r] in the
    prefill_chunks layout - tokens [pending, d_1..d_m, pad] at offset =
    the slot's lens, true_len = lens + 1 + m, q_lens = 1 + m (the
    kernel's draft-length lane), draft_lens = m for acceptance masking.
    Rows past len(tasks) are DEAD padding up to the power-of-two bucket
    (all-zero, sentinel slot dropped by the device scatter)."""
    tasks: Tuple[DraftTask, ...]
    tokens: np.ndarray       # (K_pad, spec_k + 1) int32
    offsets: np.ndarray      # (K_pad,) int32: each slot's lens
    true_lens: np.ndarray    # (K_pad,) int32: lens + 1 + m
    q_lens: np.ndarray       # (K_pad,) int32: 1 + m
    draft_lens: np.ndarray   # (K_pad,) int32: m
    row_slots: np.ndarray    # (K_pad,) int32 slot; sentinel max_batch pads


def _percentile(xs: Sequence[float], p: float) -> float:
    return float(np.percentile(np.asarray(list(xs), np.float64), p)) \
        if xs else 0.0


class TokenBudgetScheduler:
    """Host-side scheduling policy: admission queue ordering, per-tick
    chunk planning under the token budget, and latency bookkeeping.  The
    engine owns all device state and page accounting; the scheduler never
    touches jax."""

    def __init__(self, scfg: ServeConfig,
                 metrics: Optional[MetricsRegistry] = None):
        self.scfg = scfg
        self.queue: List[Request] = []
        self.finished: List[Request] = []
        # every counter below lives in the metrics registry (one typed
        # source of truth; serve/telemetry.py); the old attribute names -
        # ticks, work_clock, chunks_run, ... - remain as registry-backed
        # properties so call sites and tests keep their spelling.  A
        # standalone scheduler (unit tests) gets its own registry; the
        # engine passes its shared one in.
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        m = self.metrics
        m.counter("sched_ticks_total", "Engine ticks executed")
        m.counter("sched_work_tokens_total",
                  "Deterministic work clock: total prefill + decode tokens "
                  "executed (advances only for ACCEPTED tokens under "
                  "speculation)")
        m.counter("sched_chunks_run_total", "Prefill chunks executed")
        m.counter("sched_packs_run_total",
                  "Batched ragged chunk launches (at most 1 per tick)")
        # preemption accounting (incremented by the engine)
        m.counter("sched_preemptions_total", "Running requests shed by "
                  "priority preemption")
        m.counter("sched_resumes_total",
                  "Preempted requests re-admitted through the chunk path")
        m.counter("sched_pages_reclaimed_total",
                  "KV pages returned to the pool by preemption shedding")
        m.counter("sched_pages_parked_total", "Victim KV pages published "
                  "into the prefix tree on preemption")
        # speculative-decoding accounting (serve/drafting.py proposes,
        # the engine's verify launch accepts/rejects).  Drafted tokens
        # consume tick budget but NOT work clock: the work clock advances
        # only for ACCEPTED (emitted) tokens, so work-clock TTFT/TBT and
        # the final work_tokens total are directly comparable between
        # speculative-on and speculative-off runs of the same trace.
        m.counter("sched_spec_drafted_total",
                  "Speculative draft tokens sent to the verify launch")
        m.counter("sched_spec_accepted_total",
                  "Speculative draft tokens accepted (emitted)")
        m.counter("sched_spec_rejected_total",
                  "Speculative draft tokens rejected by the verify launch")
        # request deadlines (the engine expires through expired(); the
        # counter advances once per expired request)
        m.counter("sched_timeouts_total",
                  "Requests expired by their work-clock deadline (finished "
                  "with TIMEOUT status, pages freed the same tick)")
        # SLO-driven priority aging (incremented in pop() at admission)
        m.counter("sched_priority_boosts_total",
                  "Admissions whose work-clock-aged effective priority "
                  "exceeded the submitted priority (priority_aging)")
        m.gauge("sched_queue_depth",
                "Requests waiting for admission (RESUMING included)")
        m.gauge("sched_queue_depth_by_priority",
                "Admission queue depth per priority class",
                labelnames=("priority",))
        m.histogram("sched_spec_chain_accept_ratio",
                    "Per-chain speculative acceptance ratio "
                    "(accepted / drafted)",
                    buckets=(0.0, 0.25, 0.5, 0.75, 1.0))
        # per-tick budget accounting: (decode_tokens, prefill_tokens)
        self.tick_log: List[Tuple[int, int]] = []

    # registry-backed compatibility views (one source of truth: metrics)
    ticks = _registry_counter("sched_ticks_total")
    work_clock = _registry_counter("sched_work_tokens_total")
    chunks_run = _registry_counter("sched_chunks_run_total")
    packs_run = _registry_counter("sched_packs_run_total")
    preemptions = _registry_counter("sched_preemptions_total")
    resumes = _registry_counter("sched_resumes_total")
    pages_reclaimed = _registry_counter("sched_pages_reclaimed_total")
    pages_parked = _registry_counter("sched_pages_parked_total")
    spec_drafted = _registry_counter("sched_spec_drafted_total")
    spec_accepted = _registry_counter("sched_spec_accepted_total")
    spec_rejected = _registry_counter("sched_spec_rejected_total")
    priority_boosts = _registry_counter("sched_priority_boosts_total")
    timeouts = _registry_counter("sched_timeouts_total")

    # -- queue / admission policy -----------------------------------------
    def submit(self, req: Request):
        req.t_submit = time.time()
        req.w_submit = self.work_clock
        self.queue.append(req)

    def requeue(self, req: Request):
        """Park a preempted victim back in the queue (RESUMING).  Its
        submit stamps are NOT reset - TTFT/TBT stay monotone across the
        preempt/resume - and its uid keeps its original FIFO position, so
        within its priority class a victim resumes ahead of newcomers."""
        self.queue.append(req)

    def expired(self, req: Request) -> bool:
        """Deadline check, in the deterministic work clock: True once the
        engine has executed `deadline_tokens` work tokens since the
        request's submit without it finishing.  The ENGINE sweeps with
        this at the top of every tick and frees the expired request's slot
        and pages the same tick - a deadline can bound latency but never
        hang or strand capacity."""
        return (req.deadline_tokens is not None
                and not req.done
                and self.work_clock - req.w_submit >= req.deadline_tokens)

    def effective_priority(self, req: Request) -> int:
        """Priority used for ADMISSION ORDERING.  With priority_aging on,
        a queued (or preempted-and-parked) request gains +1 effective
        priority for every priority_age_tokens of work-clock age since it
        was submitted, so a low-priority request's wait is bounded: after
        (gap * priority_age_tokens) tokens of engine work it outranks any
        higher class and becomes the admission head.  Deterministic by
        construction - age is measured on the work clock, not wall time.
        Aging deliberately does NOT feed the preemption policy: an aged
        request admits ahead of newcomers but never evicts running work
        (base priority keeps preempt/victim cycles impossible)."""
        if not self.scfg.priority_aging:
            return req.priority
        age = self.work_clock - req.w_submit
        return req.priority + age // self.scfg.priority_age_tokens

    def peek(self) -> Optional[Request]:
        """Next admission candidate: highest EFFECTIVE priority first
        (base priority, work-clock-aged when priority_aging is on), then
        the configured policy within the class - SJF picks the shortest
        remaining prefill (stable on arrival order); FIFO the oldest."""
        if not self.queue:
            return None
        if self.scfg.admission_policy == "sjf":
            return min(self.queue,
                       key=lambda r: (-self.effective_priority(r),
                                      len(r.target), r.uid))
        return min(self.queue,
                   key=lambda r: (-self.effective_priority(r), r.uid))

    def pop(self, req: Request):
        if self.scfg.priority_aging \
                and self.effective_priority(req) > req.priority:
            self.priority_boosts += 1
        self.queue.remove(req)

    def queue_depth_by_priority(self) -> Dict[str, int]:
        """Current queue-depth gauge per priority class (RESUMING victims
        included - they are queued load like any other)."""
        out: Dict[str, int] = {}
        for r in self.queue:
            key = str(r.priority)
            out[key] = out.get(key, 0) + 1
        return out

    # -- budget shaping ----------------------------------------------------
    def prefill_budget(self, n_decode: int) -> int:
        """Tokens of prefill work this tick may carry.  Decode slots have
        already taken one token each off the top (decode is never
        descheduled); with decode_priority the remainder is additionally
        capped at max_prefill_fraction * tick_token_budget, so the work of
        a tick - and with it the work-clock TBT of every in-flight decode
        - stays bounded however deep the prefill queue is."""
        budget = self.scfg.tick_token_budget - n_decode
        if self.scfg.decode_priority:
            budget = min(budget, int(self.scfg.max_prefill_fraction
                                     * self.scfg.tick_token_budget))
        return max(budget, 0)

    # -- chunk planning ----------------------------------------------------
    def plan_chunks(self, prefilling: Sequence[Tuple[int, Request]],
                    budget: int) -> List[ChunkTask]:
        """Fill `budget` tokens with prefill chunks over the PREFILLING
        slots.  The OLDEST request (lowest uid) is guaranteed the first
        chunk - so a long prompt always advances and can never be starved
        by a stream of newcomers - then the rest of the budget goes
        SHORTEST-REMAINING-FIRST (ties broken by admission order): a
        nearly-done short prompt reaches its first token ahead of a 4k
        neighbor that would otherwise monopolize the budget, which is
        what keeps short-request TTFT flat under mixed traffic.  Each
        chunk is `prefill_chunk` tokens except a prompt's final
        remainder; a chunk only runs if it fits the remaining budget
        whole, so the budget is never exceeded and every chunk start
        stays page-aligned.  Higher-priority requests outrank the SRF
        order (priority-aware chunk fill); a resuming request's target is
        its prompt plus pre-preemption output (Request.target)."""
        if not prefilling:
            return []
        chunk = self.scfg.prefill_chunk
        srf = sorted(prefilling,
                     key=lambda sr: (-sr[1].priority,
                                     sr[1].prompt_remaining, sr[1].uid))
        # the guaranteed-progress floor goes to the oldest request OF THE
        # HIGHEST PRESENT PRIORITY CLASS: within a class no stream of
        # newcomers can starve a long prompt, while a high-priority
        # admission (e.g. one that just preempted its way in) is never
        # stuck behind a lower-priority neighbor's prefill
        oldest = min(prefilling,
                     key=lambda sr: (-sr[1].priority, sr[1].uid))
        order = [oldest] + [sr for sr in srf if sr is not oldest]
        planned: Dict[int, int] = {r.uid: r.prefill_pos for _, r in order}
        cap = self.scfg.max_chunks_per_tick or len(order) * 1_000_000
        tasks: List[ChunkTask] = []
        progressed = True
        while budget > 0 and progressed and len(tasks) < cap:
            progressed = False
            for slot, req in order:
                cursor = planned[req.uid]
                remaining = len(req.target) - cursor
                if remaining <= 0:
                    continue
                take = min(chunk, remaining)
                if take > budget:
                    continue
                tasks.append(ChunkTask(req, slot, cursor, take))
                planned[req.uid] = cursor + take
                budget -= take
                progressed = True
                if len(tasks) >= cap:
                    break
        return tasks

    def pack_chunks(self, tasks: Sequence[ChunkTask]) -> ChunkBatch:
        """Pack one tick's planned chunks into the ragged batch the
        one-launch tick executes: every task becomes a row of a
        (K_pad, prefill_chunk) token matrix with its own offset / cursor /
        owning slot, K_pad bucketed to the next power of two
        (bucket_rows) so steady-state traffic reuses a handful of
        compiled shapes.  Multiple chunks of the SAME request may share a
        batch - plan_chunks emits them in cursor order, and the batched
        kernel scatters every row's K/V before any row's attention reads
        the pool, so the later chunk sees the earlier one exactly.
        Row padding inside a chunk is masked to the null page by the
        model (pad positions of row A must never race row B's real
        writes); dead rows carry the max_batch sentinel slot the device
        scatter drops."""
        s_pad = self.scfg.prefill_chunk
        k_pad = bucket_rows(len(tasks))
        sentinel = self.scfg.max_batch
        tokens = np.zeros((k_pad, s_pad), np.int32)
        offsets = np.zeros((k_pad,), np.int32)
        true_lens = np.zeros((k_pad,), np.int32)
        final_slots = np.full((k_pad,), sentinel, np.int32)
        row_slots = np.full((k_pad,), -1, np.int32)
        for r, t in enumerate(tasks):
            tokens[r, :t.length] = t.req.target[t.start:t.start + t.length]
            offsets[r] = t.start
            true_lens[r] = t.start + t.length
            row_slots[r] = t.slot
            if t.start + t.length >= len(t.req.target):
                final_slots[r] = t.slot
        return ChunkBatch(tuple(tasks), tokens, offsets, true_lens,
                          final_slots, row_slots)

    # -- speculative drafting ----------------------------------------------
    def plan_drafts(self, decoding: Sequence[Tuple[int, Request]],
                    room: int) -> List[DraftTask]:
        """Propose draft chains for this tick's DECODING slots by n-gram
        lookup over each request's own token history (prompt + generated
        so far).  Drafted tokens consume tick budget: `room` is the
        budget left after every decode slot took its guaranteed token
        (the engine hands prefill planning what remains after drafts, so
        budget stays a hard ceiling).  Per-request caps: spec_k, and
        remaining_new - 1 so a fully accepted chain plus its bonus token
        can never overrun the generation budget - or the page
        reservation, which admission sized for exactly max_new_tokens.
        Slots are visited in slot order (deterministic); a request whose
        history never repeats gets no draft and decodes normally."""
        if room <= 0:
            return []
        scfg = self.scfg
        tasks: List[DraftTask] = []
        for slot, req in decoding:
            cap = min(scfg.spec_k, req.remaining_new - 1, room)
            if cap < 1:
                continue
            draft = ngram_draft(req.prompt + req.out_tokens, cap,
                                scfg.spec_ngram)
            if not draft:
                continue
            tasks.append(DraftTask(req, slot, -1, tuple(draft)))
            room -= len(draft)
            if room <= 0:
                break
        return tasks

    def pack_drafts(self, tasks: Sequence[DraftTask],
                    lens: np.ndarray) -> SpecBatch:
        """Pack one tick's draft chains into the ragged batch the verify
        launch scores: row r = [pending token, draft chain, pad] at
        offset lens[slot], bucketed to the next power of two like
        pack_chunks so steady-state traffic reuses a handful of compiled
        shapes.  `lens` is the engine's host lens mirror (the pending
        token of a DECODING slot is its last emitted token; its KV is
        not yet written, which is why the row starts at offset = lens
        and carries 1 + m real queries)."""
        s_spec = self.scfg.spec_k + 1
        k_pad = bucket_rows(len(tasks))
        sentinel = self.scfg.max_batch
        tokens = np.zeros((k_pad, s_spec), np.int32)
        offsets = np.zeros((k_pad,), np.int32)
        true_lens = np.zeros((k_pad,), np.int32)
        q_lens = np.zeros((k_pad,), np.int32)
        draft_lens = np.zeros((k_pad,), np.int32)
        row_slots = np.full((k_pad,), sentinel, np.int32)
        packed = []
        for r, t in enumerate(tasks):
            m = len(t.draft)
            off = int(lens[t.slot])
            tokens[r, 0] = t.req.out_tokens[-1]
            tokens[r, 1:1 + m] = t.draft
            offsets[r] = off
            true_lens[r] = off + 1 + m
            q_lens[r] = 1 + m
            draft_lens[r] = m
            row_slots[r] = t.slot
            packed.append(DraftTask(t.req, t.slot, off, t.draft))
        return SpecBatch(tuple(packed), tokens, offsets, true_lens,
                         q_lens, draft_lens, row_slots)

    def note_spec(self, drafted: int, accepted: int):
        """Record one verify lane's outcome: `drafted` tokens proposed,
        `accepted` of them emitted.  Counters only - the work clock is
        advanced by the engine per ACCEPTED token at emission time."""
        self.spec_drafted += drafted
        self.spec_accepted += accepted
        self.spec_rejected += drafted - accepted
        if drafted:
            self.metrics.get("sched_spec_chain_accept_ratio") \
                .observe(accepted / drafted)

    # -- accounting --------------------------------------------------------
    def note_work(self, n_tokens: int):
        self.work_clock += n_tokens

    def note_tick(self, decode_tokens: int, prefill_tokens: int):
        self.ticks += 1
        self.tick_log.append((decode_tokens, prefill_tokens))
        self.metrics.get("sched_queue_depth").set(len(self.queue))

    def note_token(self, req: Request, wall: float,
                   work: Optional[int] = None):
        """Stamp one emitted token.  `work` overrides the work-clock value
        recorded for it: the one-launch tick runs every chunk before any
        token value reaches the host, so it snapshots each final chunk's
        work clock at planning time and stamps the deferred emission with
        it - keeping work-clock TTFT/TBT identical to the sequential
        per-chunk path."""
        req.token_wall.append(wall)
        req.token_work.append(self.work_clock if work is None else work)
        req.token_tick.append(self.ticks)

    def note_finished(self, req: Request):
        self.finished.append(req)

    # -- stats -------------------------------------------------------------
    def token_stalls(self, reqs: Optional[Sequence[Request]] = None
                     ) -> List[int]:
        """Per-token TICK-WORK STALL: the total tokens of work the engine
        executed in the tick that emitted the token.  Tick duration is
        proportional to the work it carries, so this is the deterministic
        size of the scheduling bubble a token sat behind - a token emitted
        in the same tick as a monolithic 4k prefill is stamped ~4k, while
        a budgeted tick can never stamp more than tick_token_budget."""
        per_tick = [d + p for d, p in self.tick_log]
        return [per_tick[t] for r in (self.finished if reqs is None
                                      else reqs)
                for t in r.token_tick]

    def stats(self) -> Dict[str, float]:
        """Latency aggregates over finished requests: p50/p95 TTFT,
        time-between-tokens, and per-token tick-work stalls, in wall
        seconds and in work-clock tokens."""
        reqs = self.finished
        ttft_wall = [r.ttft_wall() for r in reqs if r.token_wall]
        ttft_work = [r.ttft_work() for r in reqs if r.token_work]
        tbt_wall = [d for r in reqs for d in r.tbt_wall()]
        tbt_work = [d for r in reqs for d in r.tbt_work()]
        stalls = self.token_stalls()
        per_tick = [d + p for d, p in self.tick_log]
        self.metrics.get("sched_queue_depth").set(len(self.queue))
        depth_by_prio = self.queue_depth_by_priority()
        for prio, n in depth_by_prio.items():
            self.metrics.get("sched_queue_depth_by_priority") \
                .labels(prio).set(n)
        return {
            "requests": len(reqs),
            "ticks": self.ticks,
            "work_tokens": self.work_clock,
            "chunks_run": self.chunks_run,
            "packs_run": self.packs_run,
            "preemptions": self.preemptions,
            "resumes": self.resumes,
            "pages_reclaimed": self.pages_reclaimed,
            "pages_parked": self.pages_parked,
            "spec_drafted": self.spec_drafted,
            "spec_accepted": self.spec_accepted,
            "spec_rejected": self.spec_rejected,
            "spec_acceptance_rate": self.spec_accepted / self.spec_drafted
            if self.spec_drafted else 0.0,
            "spec_chain_accept_mean":
            self.metrics.get("sched_spec_chain_accept_ratio").mean,
            "priority_boosts": self.priority_boosts,
            "timeouts": self.timeouts,
            "queue_depth": len(self.queue),
            "queue_depth_by_priority": depth_by_prio,
            "max_tick_tokens": max(per_tick) if per_tick else 0,
            "ttft_wall_p50": _percentile(ttft_wall, 50),
            "ttft_wall_p95": _percentile(ttft_wall, 95),
            "tbt_wall_p50": _percentile(tbt_wall, 50),
            "tbt_wall_p95": _percentile(tbt_wall, 95),
            "ttft_work_p50": _percentile(ttft_work, 50),
            "ttft_work_p95": _percentile(ttft_work, 95),
            "tbt_work_p50": _percentile(tbt_work, 50),
            "tbt_work_p95": _percentile(tbt_work, 95),
            "stall_work_p50": _percentile(stalls, 50),
            "stall_work_p95": _percentile(stalls, 95),
            "stall_work_max": max(stalls) if stalls else 0,
        }
