"""Synthetic LM data pipeline: deterministic, restartable, shard-aware.

Produces batches deterministically from (seed, step) so a restarted trainer
resumes mid-epoch with byte-identical data (fault-tolerance requirement).
Host arrays are placed onto the mesh with the same batch sharding the train
step expects; a background prefetch thread hides host latency.
"""
from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, Optional

import jax
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from ..configs.base import ModelConfig, TrainConfig


class SyntheticCorpus:
    """Zipf-distributed token stream with local n-gram structure, so the
    model has something learnable (repeated bigram templates)."""

    def __init__(self, vocab_size: int, seed: int = 0, n_templates: int = 64,
                 template_len: int = 16):
        self.vocab = vocab_size
        rng = np.random.default_rng(seed)
        probs = 1.0 / np.arange(1, vocab_size + 1) ** 1.1
        self.probs = probs / probs.sum()
        self.templates = rng.integers(
            0, vocab_size, (n_templates, template_len)).astype(np.int32)

    def batch(self, step: int, batch_size: int, seq_len: int) -> np.ndarray:
        rng = np.random.default_rng((hash(("batch", step)) & 0x7FFFFFFF))
        toks = rng.choice(self.vocab, size=(batch_size, seq_len),
                          p=self.probs).astype(np.int32)
        # splice learnable templates
        n_splice = max(1, seq_len // (2 * self.templates.shape[1]))
        for b in range(batch_size):
            for _ in range(n_splice):
                t = rng.integers(0, len(self.templates))
                pos = rng.integers(0, max(1, seq_len - self.templates.shape[1]))
                toks[b, pos:pos + self.templates.shape[1]] = self.templates[t]
        return toks


class DataPipeline:
    """step -> device-placed batch dict, with prefetch."""

    def __init__(self, cfg: ModelConfig, tcfg: TrainConfig,
                 mesh=None, prefetch: int = 2):
        self.cfg = cfg
        self.tcfg = tcfg
        self.mesh = mesh
        self.corpus = SyntheticCorpus(cfg.vocab_size, seed=tcfg.seed)
        self._q: "queue.Queue" = queue.Queue(maxsize=prefetch)
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()

    # ---- raw host batches --------------------------------------------------
    def host_batch(self, step: int) -> Dict[str, np.ndarray]:
        B, S = self.tcfg.global_batch, self.tcfg.seq_len
        cfg = self.cfg
        batch: Dict[str, np.ndarray] = {}
        if cfg.family == "vlm":
            s_text = S - cfg.frontend_tokens
            batch["tokens"] = self.corpus.batch(step, B, s_text)
            rng = np.random.default_rng(step + 7)
            batch["vision_embeds"] = rng.standard_normal(
                (B, cfg.frontend_tokens, cfg.d_model)).astype(np.float32) * 0.02
        elif cfg.family == "audio":
            batch["tokens"] = self.corpus.batch(step, B, S)
            rng = np.random.default_rng(step + 11)
            batch["audio_embeds"] = rng.standard_normal(
                (B, S, cfg.d_model)).astype(np.float32) * 0.02
        else:
            batch["tokens"] = self.corpus.batch(step, B, S)
        return batch

    # ---- device placement ---------------------------------------------------
    def device_batch(self, step: int) -> Dict:
        hb = self.host_batch(step)
        if self.mesh is None:
            return {k: jax.numpy.asarray(
                v if k == "tokens" else v.astype(jax.numpy.bfloat16))
                for k, v in hb.items()}
        out = {}
        dp = tuple(a for a in ("pod", "data") if a in self.mesh.axis_names)
        for k, v in hb.items():
            spec = P(dp, *([None] * (v.ndim - 1)))
            arr = v if k == "tokens" else v.astype(jax.numpy.bfloat16)
            out[k] = jax.device_put(arr, NamedSharding(self.mesh, spec))
        return out

    # ---- prefetch -------------------------------------------------------------
    def start(self, first_step: int):
        def worker():
            step = first_step
            while not self._stop.is_set():
                try:
                    self._q.put((step, self.device_batch(step)), timeout=0.5)
                    step += 1
                except queue.Full:
                    continue
        self._thread = threading.Thread(target=worker, daemon=True)
        self._thread.start()

    def next(self):
        return self._q.get()

    def stop(self):
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=2.0)
