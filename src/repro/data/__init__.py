from .pipeline import DataPipeline, SyntheticCorpus

__all__ = ["DataPipeline", "SyntheticCorpus"]
