"""JAX version-compat shims (0.4.x through 0.6+).

The repo targets the current jax API (``jax.shard_map``, ``jax.set_mesh``,
``jax.sharding.AxisType``, ``pltpu.CompilerParams``); older releases spell
these differently or lack them.  Product code imports the shims from here so
one import site owns the version probing.  Pallas-specific aliases live in
``kernels/pallas_compat.py`` (kept separate so importing this module never
pulls in Pallas).
"""
from __future__ import annotations

import jax


def make_mesh(shape, axes):
    """jax.make_mesh with Auto axis types when the installed jax has them."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(tuple(shape), tuple(axes),
                             axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(tuple(shape), tuple(axes))


def shard_map(f, mesh, in_specs, out_specs):
    """jax.shard_map with replication checking off (check_vma / check_rep)."""
    smap = getattr(jax, "shard_map", None)
    if smap is not None:
        return smap(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                    check_vma=False)
    from jax.experimental.shard_map import shard_map as smap_old
    return smap_old(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                    check_rep=False)


def use_mesh(mesh):
    """Context manager activating `mesh` (jax.set_mesh, or `with mesh:`)."""
    setter = getattr(jax, "set_mesh", None)
    if setter is not None:
        return setter(mesh)
    return mesh       # jax < 0.6: Mesh itself is the context manager


def axis_size(name: str):
    """Static size of a mapped axis inside shard_map.

    jax.lax.axis_size is recent; psum of a Python literal constant-folds to
    the axis size at trace time on every release, so it stays usable in
    shape arithmetic."""
    getter = getattr(jax.lax, "axis_size", None)
    if getter is not None:
        return getter(name)
    return jax.lax.psum(1, name)


@jax.custom_vjp
def optimization_barrier(x):
    """jax.lax.optimization_barrier with an explicit VJP.

    Old jax releases have no differentiation rule for the barrier primitive;
    wiring the rule ourselves also keeps the barrier on the COTANGENT, so the
    backward pass gets the same hoisting protection as the forward.
    """
    return jax.lax.optimization_barrier(x)


def _barrier_fwd(x):
    return optimization_barrier(x), None


def _barrier_bwd(_, g):
    return (jax.lax.optimization_barrier(g),)


optimization_barrier.defvjp(_barrier_fwd, _barrier_bwd)


__all__ = ["axis_size", "make_mesh", "optimization_barrier", "shard_map",
           "use_mesh"]
